"""Serve a small model with batched requests through the CARE dispatcher.

The paper's own setting at the serving tier: requests are jobs, replica
groups are servers, and the front-end routes each request by JSAQ over
*approximated* per-replica occupancy.  Replicas mirror the dispatcher's
emulation (the paper's information asymmetry) and send a correction
message only when the error reaches x (ET-x).

Two parts:

1. **Real decode**: a reduced SmolLM-family model is prefilled on a batch
   of prompts and decoded with continuous batching -- the actual
   ``model.prefill`` / ``model.decode_step`` code path the full-size
   configs lower to on the 512-chip mesh.
2. **Dispatch at scale**: the jax serving engine drives the whole regime
   ladder (exact / ET-x / DT-x / RT-r) as *fused grids* -- one compiled
   program per comm kind, thresholds traced -- and compares dispatchers
   on job completion time and messages per completion (paper Figs 8-12 at
   the systems tier).  The routing-policy suite rides the same grids:
   SQ(2) and round robin under ET, and drain-time-aware JSAQ under 2:1
   heterogeneous replica speeds.  The rate profile is a traced operand:
   the uniform RR control passes explicit all-ones rates, so it shares
   one compiled program with the 2:1 RR cell (only the *presence* of
   rates is structural).
   The numpy ``CareDispatcher`` remains the pluggable path (hook a real
   ``decode_step`` closure via ``model_fn``) and the golden reference:
   one cell is re-run through it here and checked bit-identical to the
   fused grid.

Usage:
  PYTHONPATH=src python examples/serve_care.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serve import engine
from repro.serve.engine import ServeConfig


def real_decode_demo(num_prompts: int = 4, prompt_len: int = 16, gen_len: int = 12):
    """Continuous-batched generation with the real model code path."""
    cfg = get_config("smollm-135m").reduced()
    params = model.init_params(jax.random.key(0), cfg)

    tokens = jax.random.randint(
        jax.random.key(1), (num_prompts, prompt_len), 0, cfg.vocab_size
    )
    cache_len = prompt_len + gen_len
    logits, cache = model.prefill(
        params, {"tokens": tokens}, cfg, cache_len=cache_len
    )
    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, cfg)
    )
    out = [jnp.argmax(logits, axis=-1)]
    for i in range(gen_len - 1):
        logits, cache = decode(params, out[-1], cache, jnp.asarray(prompt_len + i))
        out.append(jnp.argmax(logits, axis=-1))
    gen = jnp.stack(out, axis=1)
    assert gen.shape == (num_prompts, gen_len)
    assert not bool(jnp.isnan(logits).any())
    print(f"[decode] generated {gen.shape} tokens with batched continuous "
          f"decode ({cfg.name}); sample row: {np.asarray(gen[0])[:8]}...")


def dispatch_comparison(slots: int, load: float):
    print(f"\n[dispatch] {slots} slots at load {load}, 8 replica groups x 16 "
          f"decode slots (fused jax grids, one program per comm kind)")
    # MSR drain = decode_slots / mean_work = 0.25: the emulation runs at
    # the nominal per-replica completion rate (and stays dyadic, so the
    # f32 traced engine is bit-identical to the f64 numpy reference).
    work = dict(slots=slots, load=load, mean_prefill=4, mean_decode=60,
                msr_drain=0.25)
    hetero = (2.0, 2.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0)  # 2:1 speeds
    named = [
        ("exact", ServeConfig(comm="exact", **work)),
        ("ET-4 (CARE)", ServeConfig(comm="et", x=4, **work)),
        ("ET-8 (CARE)", ServeConfig(comm="et", x=8, **work)),
        ("DT-4", ServeConfig(comm="dt", x=4, **work)),
        ("RT-16", ServeConfig(comm="rt", rt_period=16, **work)),
        # The policy suite composes with the same ET trigger: SQ(2) and
        # round robin over CARE state, and the drain-time-aware router
        # under 2:1 heterogeneous replica speeds (RR is rate-blind and
        # pays for it; drain/JSAQ hold the exact-state JCT).  The uniform
        # RR control carries explicit all-ones rates so the 2:1 cell
        # shares its compiled program (rates are traced operands).
        ("ET-4 SQ(2)", ServeConfig(comm="et", x=4, policy="sqd", **work)),
        ("ET-4 RR",
         ServeConfig(comm="et", x=4, policy="rr",
                     decode_rates=(1.0,) * 8, **work)),
        ("ET-4 RR 2:1",
         ServeConfig(comm="et", x=4, policy="rr", decode_rates=hetero,
                     **work)),
        ("ET-4 drain 2:1",
         ServeConfig(comm="et", x=4, policy="drain", decode_rates=hetero,
                     **work)),
    ]
    groups: dict = {}
    for i, (_, cell) in enumerate(named):
        groups.setdefault(cell.static_part(), []).append(i)
    results: dict = {}
    for static, idxs in groups.items():
        grid = engine.serve_grid([0], static, [named[i][1] for i in idxs])
        for i, row in zip(idxs, grid):
            results[i] = row[0]
    print(f"{len(named)} cells ran as {len(groups)} compiled programs "
          f"(thresholds are traced operands)")
    print(f"{'dispatcher':<14} {'mean JCT':>9} {'p99 JCT':>9} {'msgs/completion':>16}")
    for i, (name, _) in enumerate(named):
        r = results[i]
        print(f"{name:<14} {r.mean_jct:9.1f} {r.p99_jct:9.1f} "
              f"{r.msgs_per_completion:16.3f}")

    # The numpy dispatcher stays as the pluggable-model_fn path and the
    # golden reference: replay one cell through it and check bit-identity.
    cell = named[1][1]
    ref = engine.run_serving_sim(
        cell.engine_config(), slots=cell.slots, load=cell.load,
        mean_prefill=cell.mean_prefill, mean_decode=cell.mean_decode,
        seed=0, workload=engine.workload_for(cell, 0),
    )
    jx = results[1]
    assert ref["messages"] == jx.messages
    assert np.array_equal(ref["jct_by_rid"], jx.jct_by_rid)
    print("\n[golden] numpy CareDispatcher replay of ET-4: "
          f"{ref['messages']} messages, JCT vector bit-identical to the "
          "fused grid")
    print("\nReading: the ET dispatcher matches the exact-state JCT "
          "distribution while replicas\nmessage the front-end only on "
          "emulation-error threshold crossings.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=20_000)
    ap.add_argument("--load", type=float, default=0.9)
    args = ap.parse_args()
    real_decode_demo()
    dispatch_comparison(args.slots, args.load)


if __name__ == "__main__":
    main()
