"""Serve a small model with batched requests through the CARE dispatcher.

The paper's own setting at the serving tier: requests are jobs, replica
groups are servers, and the front-end routes each request by JSAQ over
*approximated* per-replica occupancy.  Replicas mirror the dispatcher's
emulation (the paper's information asymmetry) and send a correction
message only when the error reaches x (ET-x).

Two parts:

1. **Real decode**: a reduced SmolLM-family model is prefilled on a batch
   of prompts and decoded with continuous batching -- the actual
   ``model.prefill`` / ``model.decode_step`` code path the full-size
   configs lower to on the 512-chip mesh.
2. **Dispatch at scale**: the queueing engine drives 20k slots under a
   0.9 load and compares ET-x / DT-x / RT-r / exact dispatchers on job
   completion time and messages per completion (paper Figs 8-12 at the
   systems tier).

Usage:
  PYTHONPATH=src python examples/serve_care.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serve.engine import EngineConfig, run_serving_sim


def real_decode_demo(num_prompts: int = 4, prompt_len: int = 16, gen_len: int = 12):
    """Continuous-batched generation with the real model code path."""
    cfg = get_config("smollm-135m").reduced()
    params = model.init_params(jax.random.key(0), cfg)

    tokens = jax.random.randint(
        jax.random.key(1), (num_prompts, prompt_len), 0, cfg.vocab_size
    )
    cache_len = prompt_len + gen_len
    logits, cache = model.prefill(
        params, {"tokens": tokens}, cfg, cache_len=cache_len
    )
    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, cfg)
    )
    out = [jnp.argmax(logits, axis=-1)]
    for i in range(gen_len - 1):
        logits, cache = decode(params, out[-1], cache, jnp.asarray(prompt_len + i))
        out.append(jnp.argmax(logits, axis=-1))
    gen = jnp.stack(out, axis=1)
    assert gen.shape == (num_prompts, gen_len)
    assert not bool(jnp.isnan(logits).any())
    print(f"[decode] generated {gen.shape} tokens with batched continuous "
          f"decode ({cfg.name}); sample row: {np.asarray(gen[0])[:8]}...")


def dispatch_comparison(slots: int, load: float):
    print(f"\n[dispatch] {slots} slots at load {load}, 8 replica groups x 16 "
          f"decode slots")
    print(f"{'dispatcher':<14} {'mean JCT':>9} {'p99 JCT':>9} {'msgs/completion':>16}")
    rows = [
        ("exact", EngineConfig(comm="exact")),
        ("ET-4 (CARE)", EngineConfig(comm="et", et_x=4)),
        ("ET-8 (CARE)", EngineConfig(comm="et", et_x=8)),
        ("DT-4", EngineConfig(comm="dt", dt_x=4)),
        ("RT-16", EngineConfig(comm="rt", rt_period=16)),
    ]
    base = None
    for name, ecfg in rows:
        r = run_serving_sim(ecfg, slots=slots, load=load)
        if base is None:
            base = r
        print(f"{name:<14} {r['mean_jct']:9.1f} {r['p99_jct']:9.1f} "
              f"{r['msgs_per_completion']:16.3f}")
    print("\nReading: the ET dispatcher matches the exact-state JCT "
          "distribution while replicas\nmessage the front-end only on "
          "emulation-error threshold crossings.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=20_000)
    ap.add_argument("--load", type=float, default=0.9)
    args = ap.parse_args()
    real_decode_demo()
    dispatch_comparison(args.slots, args.load)


if __name__ == "__main__":
    main()
