"""End-to-end driver: train a MoE LM with the CARE expert balancer.

Demonstrates the full training substrate on a DeepSeek-V2-family model:

* data pipeline -> train step (microbatch accumulation) -> AdamW;
* the CARE balancer: a skewed gate is rebalanced by the JSAQ PI bias
  driven by the *approximated* expert load, with exact-count syncs fired
  sparsely by the ET trigger (the paper's server-side-adaptive pattern);
* fault tolerance: an atomic checkpoint every --ckpt-every steps, a
  simulated crash at the midpoint, and an automatic restore-and-resume --
  the loss curve continues exactly where it left off.

The default config is the reduced (CPU-sized) DeepSeek-V2 family; pass
``--full-size`` on a real cluster to train the assigned 236B config
(the same code path the multi-pod dry-run lowers for 512 chips).

Usage:
  PYTHONPATH=src python examples/train_moe_care.py --steps 200
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.configs.base import CareConfig
from repro.core import moe_balancer
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.optim import adamw
from repro.train import train_loop

GATE_SKEW = 1.5


def build_state(cfg, seed: int = 0):
    state = train_loop.init_state(jax.random.key(seed), cfg)
    # Inject a persistent expert skew -- the imbalance the balancer must fix.
    g = state.params["layers"]["moe"]["gate"]
    e = g.shape[-1]
    mult = 1.0 + GATE_SKEW * jax.nn.one_hot(0, e) + 0.7 * GATE_SKEW * jax.nn.one_hot(1, e)
    state.params["layers"]["moe"]["gate"] = g * mult[None, None, :]
    return state


def train(cfg, steps, ckpt_dir, *, batch, seq, ckpt_every, crash_at=None):
    opt_cfg = adamw.OptimConfig(lr=3e-4, total_steps=steps, warmup_steps=10)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)

    start = checkpoint.latest_step(ckpt_dir)
    if start is None:
        state, start = build_state(cfg), 0
    else:
        state, start = checkpoint.restore(build_state(cfg), ckpt_dir)
        print(f"  [restore] resumed from checkpoint at step {start}")

    loader = ShardedLoader(data_cfg, start_step=start)
    step_fn = jax.jit(train_loop.make_train_step(cfg, opt_cfg, None, sync=False))
    sync_fn = jax.jit(lambda b: moe_balancer.sync(b, cfg.care))

    syncs, imb_first, imb_last = 0, None, None
    pending = False
    for step in range(start, steps):
        batch_arrs = next(loader)
        prev = state.balancer.true_counts
        state, metrics = step_fn(state, batch_arrs)
        counts = np.asarray(state.balancer.true_counts - prev)
        imb = float((counts.max(-1) / (counts.mean(-1) + 1e-9)).mean())
        imb_first = imb if imb_first is None else imb_first
        imb_last = imb
        if pending:  # ET trigger raised last step -> sync now (1-bit flag)
            state = dataclasses.replace(state, balancer=sync_fn(state.balancer))
            syncs += 1
        pending = bool(metrics["sync_trigger"])
        if (step + 1) % 25 == 0:
            print(f"  step {step+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"expert max/mean {imb:.2f}  syncs {syncs}")
        if (step + 1) % ckpt_every == 0:
            checkpoint.save(state, ckpt_dir, step + 1)
        if crash_at is not None and step + 1 == crash_at:
            print(f"  [crash] simulated failure at step {step+1}")
            return {"crashed": True, "imb_first": imb_first}
    return {"crashed": False, "imb_first": imb_first, "imb_last": imb_last,
            "syncs": syncs, "loss": float(metrics["loss"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--arch", default="deepseek-v2-236b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, care=CareConfig(enabled=True, comm="et", x=2), remat=False)

    ckpt_dir = tempfile.mkdtemp(prefix="care_moe_")
    try:
        crash = args.steps // 2
        print(f"[train] {cfg.name}: {args.steps} steps, simulated crash at {crash}")
        r0 = train(cfg, args.steps, ckpt_dir, batch=args.batch, seq=args.seq,
                   ckpt_every=args.ckpt_every, crash_at=crash)
        assert r0["crashed"], "expected the simulated crash"
        print("[train] relaunching after crash (restores latest checkpoint)")
        r = train(cfg, args.steps, ckpt_dir, batch=args.batch, seq=args.seq,
                  ckpt_every=args.ckpt_every)
        print(f"\n[done] expert imbalance {r0['imb_first']:.2f} -> {r['imb_last']:.2f} "
              f"(1.0 = perfect) with {r['syncs']} balancer syncs over "
              f"{args.steps} steps; final loss {r['loss']:.4f}")
        if r["syncs"] == 0 and cfg.care.comm == "et":
            print("      (0 syncs is the expected ET outcome here: a single "
                  "in-process dispatcher\n       observes every arrival, so "
                  "its emulation error is exactly zero -- Remark 4.6.\n"
                  "       Multi-dispatcher sync traffic is exercised by "
                  "benchmarks/bench_moe_balance.py\n       section B and by "
                  "the sync-variant dry-run program.)")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
