"""Soak a serving cell through the segment engine in bounded memory.

The paper's headline claims (asymptotically optimal JCT at sparse message
rates) are *steady-state* statements, so they want soak-style traces far
past what the fixed-horizon engine can materialise.  The segment engine
(``engine.serve_stream``) runs the same bit-identical dynamics chunk by
chunk: a jitted step carries the whole engine state pytree across chunks
with donated buffers while the host samples the next workload slab during
the current chunk's device execution -- memory is O(chunk), not O(slots).

This example runs a 1e6-slot diurnal soak (arrival rate modulated
sinusoidally over a simulated day) at high load, discards a warmup
transient, and prints the steady-state JCT quantiles (from the on-device
log-bucket histogram) and the long-run message rate.  Host memory stays
flat no matter how long the soak runs -- crank ``--slots`` to 1e8 and the
peak is the same.

Usage:
  PYTHONPATH=src python examples/serve_stream.py
  PYTHONPATH=src python examples/serve_stream.py --slots 10000000
"""
import argparse
import time

from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=1_000_000)
    ap.add_argument("--chunk", type=int, default=8192)
    ap.add_argument("--warmup", type=int, default=None,
                    help="slots discarded from the JCT accumulators "
                         "(default: 10%% of the horizon)")
    ap.add_argument("--load", type=float, default=0.95)
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--comm", default="et")
    ap.add_argument("--x", type=float, default=4.0)
    ap.add_argument("--diurnal-amp", type=float, default=0.3)
    ap.add_argument("--diurnal-period", type=int, default=0,
                    help="slots per simulated day (default: horizon / 4)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    warmup = args.warmup if args.warmup is not None else args.slots // 10
    period = args.diurnal_period or max(args.slots // 4, 1)
    cell = engine.ServeConfig(
        replicas=args.replicas, decode_slots=8, slots=args.slots,
        load=args.load, comm=args.comm, x=args.x, queue_cap=512,
    )
    print(f"[stream] {args.slots:,} slots, chunk={args.chunk}, "
          f"warmup={warmup:,}, load={args.load}, comm={args.comm}-"
          f"{args.x:g}, diurnal amp={args.diurnal_amp} "
          f"period={period:,}")

    t0 = time.perf_counter()
    res = engine.serve_stream(
        args.seed, cell, chunk=args.chunk, warmup=warmup,
        diurnal_amp=args.diurnal_amp, diurnal_period=period,
    )
    wall = time.perf_counter() - t0

    s = res.jct_summary()
    print(f"[stream] done in {wall:.1f}s "
          f"({res.slots / wall:,.0f} slots/s)")
    print(f"  offered={res.offered:,} completed={res.completed:,} "
          f"dropped={res.dropped:,} net_drops={res.net_drops:,}")
    print(f"  steady-state JCT (n={s['count']:,}, warmup-discarded): "
          f"mean={s['mean']:.1f} p50={s['p50']:.0f} p90={s['p90']:.0f} "
          f"p99={s['p99']:.0f} p999={s['p999']:.0f} max={s['max']}")
    print(f"  messages={res.messages:,} "
          f"({res.msgs_per_slot:.3f}/slot, "
          f"{res.msgs_per_completion:.3f}/completion)")


if __name__ == "__main__":
    main()
