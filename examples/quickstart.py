"""Quickstart: the paper's result in 60 seconds.

Runs the paper's own simulation setting (Section 9: K=30 servers, load
0.95, Geometric(1/K) services) and compares Join-the-Shortest-
Approximated-Queue under ET-x + MSR -- the paper's recommended sparse-
communication design -- against the exact-state JSQ, SQ(2) and Round
Robin baselines, on the *same* arrival/size sample paths.

The whole comparison is submitted through ``simulate_grid``: cells are
grouped by their compile-time structure (policy/comm/approx kinds) and
each group runs as **one compiled program** -- the ET-x ladder is a
single traced sweep, not four separate compiles.

Expected outcome (paper Figs 3/10/12): ET-3 + MSR matches SQ(2) while
using ~10% of JSQ's messages, and still beats Round Robin below 2%.

Usage:
  PYTHONPATH=src python examples/quickstart.py [--slots 100000]
"""
import argparse

from repro.core.care import metrics, slotted_sim
from repro.core.care.slotted_sim import SimConfig, exact_state_messages


def jct_stats(res) -> str:
    s = metrics.jct_summary(res.jct)  # zero-completion safe
    return (
        f"mean={s['mean']:7.1f}  p50={s['p50']:6.0f}  p99={s['p99']:7.0f}"
    )


def simulate_cells(cfgs, seed: int):
    """Run every cell, fused: one ``simulate_grid`` call per static group.

    Returns one ``SimResult`` per config, in order.  Cells that share a
    ``StaticConfig`` (e.g. the ET-x ladder: x is a traced operand) share
    one compiled program; the number of programs is O(#kinds), not
    O(#cells).
    """
    groups: dict = {}
    for i, cfg in enumerate(cfgs):
        groups.setdefault(cfg.static_part(), []).append(i)
    results = [None] * len(cfgs)
    for static, idxs in groups.items():
        grid = slotted_sim.simulate_grid(
            [seed], static, [cfgs[i].scenario() for i in idxs]
        )
        for i, cell in zip(idxs, grid):
            results[i] = cell[0]
    return results, len(groups)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=100_000)
    ap.add_argument("--load", type=float, default=0.95)
    ap.add_argument("--servers", type=int, default=30)
    args = ap.parse_args()

    base = dict(servers=args.servers, slots=args.slots, load=args.load)
    seed = 7  # same seed => same arrivals & job sizes for every policy

    policies = [
        ("JSQ (exact state)", SimConfig(policy="jsq", comm="none", **base)),
        ("SQ(2)", SimConfig(policy="sq2", comm="none", **base)),
        ("Round Robin", SimConfig(policy="rr", comm="none", **base)),
        ("JSAQ ET-2 + MSR", SimConfig(policy="jsaq", comm="et", x=2, approx="msr", **base)),
        ("JSAQ ET-3 + MSR", SimConfig(policy="jsaq", comm="et", x=3, approx="msr", **base)),
        ("JSAQ ET-5 + MSR", SimConfig(policy="jsaq", comm="et", x=5, approx="msr", **base)),
        ("JSAQ ET-8 + MSR", SimConfig(policy="jsaq", comm="et", x=8, approx="msr", **base)),
        ("JSAQ DT-3 + MSR-3", SimConfig(policy="jsaq", comm="dt", x=3, approx="msr_x", **base)),
    ]

    results, n_groups = simulate_cells([cfg for _, cfg in policies], seed)
    print(f"K={args.servers} servers, load={args.load}, {args.slots} slots "
          f"(identical inputs per policy;\n{len(policies)} cells fused into "
          f"{n_groups} compiled programs)\n")
    print(f"{'policy':<20} {'JCT (slots)':<38} {'msgs/dep':>9} {'rel comm':>9} {'max AQ':>7}")
    jsq_msgs = None
    for (name, cfg), res in zip(policies, results):
        msgs = exact_state_messages(res, cfg.policy, cfg.sqd)
        if jsq_msgs is None:
            jsq_msgs = max(msgs, 1)
        rel = msgs / jsq_msgs
        print(
            f"{name:<20} {jct_stats(res):<38} "
            f"{msgs / max(res.departures, 1):9.3f} {rel:9.2%} {res.max_aq:7d}"
        )
    print(
        "\nReading: ET-x + MSR holds the approximation error at <= x-1 "
        "(Thm 2.3) while the\nmessage rate decays quadratically in x "
        "(Thms 2.4/2.5) -- JSQ-like completion times\nat a few percent of "
        "the exact-state communication."
    )
    print("\nNext: examples/train_moe_care.py  (CARE inside MoE training)"
          "\n      examples/serve_care.py      (CARE request dispatcher)"
          "\n      examples/multipod_dryrun.py (512-chip AOT lowering)")


if __name__ == "__main__":
    main()
