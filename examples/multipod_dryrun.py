"""AOT-lower one (arch x shape) cell onto the 512-chip production mesh.

Shows the public launch API: build the multi-pod mesh, construct
ShapeDtypeStruct stand-ins for every input (no allocation), lower + compile
the train/prefill/decode step, and read back the memory / cost /
collective analysis that feeds EXPERIMENTS.md Section Roofline.

This is the "would it run on the cluster?" proof: a sharding mismatch, a
compile-time OOM or an unsupported collective fails here, on a laptop,
before any TPU time is spent.

Usage:
  python examples/multipod_dryrun.py --arch qwen3-0.6b --shape train_4k
  python examples/multipod_dryrun.py --arch deepseek-v2-236b --shape decode_32k
"""
# The device-count override MUST precede every jax import (jax locks the
# device count at first initialisation) -- same contract as launch/dryrun.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true",
                    help="16x16 (256 chips) instead of 2x16x16 (512)")
    args = ap.parse_args()

    from repro.launch import dryrun, hlo_analysis  # noqa: E402 (after XLA_FLAGS)

    multi_pod = not args.single_pod
    mesh_name = "2x16x16 (pod,data,model)" if multi_pod else "16x16 (data,model)"
    print(f"[dryrun] lowering {args.arch} / {args.shape} onto {mesh_name}")

    lowered, mesh, cfg, scan_trips = dryrun.lower_cell(
        args.arch, args.shape, multi_pod=multi_pod
    )
    compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    analysis = hlo_analysis.analyze_module(compiled.as_text(), scan_trips)

    gib = 1 << 30
    print(f"  chips:                {mesh.devices.size}")
    print(f"  per-chip arguments:   {mem.argument_size_in_bytes / gib:8.2f} GiB")
    print(f"  per-chip temporaries: {mem.temp_size_in_bytes / gib:8.2f} GiB")
    print(f"  per-chip HLO flops:   {analysis['flops']:.3e}")
    print(f"  per-chip HBM bytes:   {analysis['bytes_hbm']:.3e}")
    coll = analysis["collectives"]
    print(f"  collective bytes/chip: {coll['total']:.3e}  "
          f"({', '.join(f'{k}={v:.2e}' for k, v in sorted(coll.items()) if k != 'total')})")
    # jaxlib returns one properties dict (older versions wrapped it in a
    # single-element list).
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    print(f"  xla cost_analysis flops (loop bodies once): {cost.get('flops', 0):.3e}")
    print("\n  -> compiles cleanly; the sharding is coherent for this mesh.")


if __name__ == "__main__":
    main()
